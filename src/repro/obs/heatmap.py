"""Per-bin contention attribution from a committed index stream.

The paper's verdict ("the shared-memory atomic unit is the bottleneck")
is a scalar; this module answers *which bins* carry the contention and
*when*.  From the same committed index stream the trace provider feeds
``trace_from_indices`` it computes, fully columnar:

* per-bin **hits** — committed updates per destination bin
  (``np.bincount`` over the stream; sums to the stream length);
* per-bin **replays** — serialized commits: hits minus the number of
  distinct commit groups the bin appears in, i.e. every committed
  update beyond the first to a bin inside one commit group had to
  replay behind it.  This is the measure that separates §5's ``hist``
  from ``hist2``: identical per-bin hit totals, but the per-lane
  channel rotation spreads each commit group over more distinct bins,
  so the hottest bin's replay share drops strictly;
* per-bin **max wave degree** — the worst serialization degree of any
  wave that touches the bin;
* the per-wave **contention series** — degree over wave time, taken
  verbatim from the same ``WaveTrace`` the provider aggregates, so
  "the skew peaks in waves 40-60" reads straight off the array.

Bit-consistency: ``Heatmap.counters`` is built from the identical
stream via the identical ``trace_from_indices`` /
``CounterSet.from_trace`` calls ``TraceProvider.collect`` makes, so it
is bitwise-equal to what ``Session.profile`` reports for the same spec
(asserted by ``tests/test_obs.py``), and ``hits.sum()`` equals the
committed stream length exactly.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import counters as counters_mod
from repro.core.counters import COMMIT_GROUP, LANES, CounterSet

__all__ = ["Heatmap", "heatmap_from_stream", "heatmap_for_spec",
           "DEFAULT_HOT_DEGREE"]

#: a bin is "hot" when some wave touching it serialized at least this much
DEFAULT_HOT_DEGREE = 2.0


@dataclasses.dataclass(frozen=True)
class Heatmap:
    """Per-bin/per-wave contention attribution for one workload point."""

    label: str
    num_slots: int              # addressable destination bins (max id + 1)
    bins: np.ndarray            # (K,) touched bin ids, ascending
    hits: np.ndarray            # (K,) committed updates per bin
    replays: np.ndarray         # (K,) serialized replays per bin
    max_wave_degree: np.ndarray  # (K,) worst degree of any wave hitting bin
    wave_degree: np.ndarray     # (W,) contention series over wave time
    counters: CounterSet        # bitwise-equal to TraceProvider.collect
    hot_degree: float = DEFAULT_HOT_DEGREE
    lanes: int = LANES
    commit_group: int = COMMIT_GROUP
    meta: dict = dataclasses.field(default_factory=dict)

    # -- derived ----------------------------------------------------------

    @property
    def total_hits(self) -> int:
        """Committed stream length; equals ``int(hits.sum())`` exactly."""
        return int(self.hits.sum()) if self.hits.size else 0

    @property
    def num_waves(self) -> int:
        return int(self.wave_degree.shape[0])

    @property
    def hot_mask(self) -> np.ndarray:
        """Bins that ever serialized: wave degree over threshold + replays."""
        return (self.max_wave_degree >= self.hot_degree) & (self.replays > 0)

    @property
    def hot_bins(self) -> np.ndarray:
        return self.bins[self.hot_mask]

    @property
    def top_bin(self) -> Optional[int]:
        """Bin carrying the most serialized replays (lowest id on ties)."""
        if not self.bins.size or not self.replays.any():
            return None
        return int(self.bins[int(np.argmax(self.replays))])

    @property
    def top_bin_share(self) -> float:
        """Fraction of ALL committed updates that are replays behind the
        single hottest bin — the §5 localization metric (hist > hist2)."""
        total = self.total_hits
        if not total or not self.replays.size:
            return 0.0
        return float(self.replays.max()) / float(total)

    @property
    def peak_wave(self) -> Optional[int]:
        return int(np.argmax(self.wave_degree)) if self.num_waves else None

    @property
    def peak_degree(self) -> float:
        return float(self.wave_degree.max()) if self.num_waves else 0.0

    def top(self, k: int = 16) -> np.ndarray:
        """Indices into the bin arrays of the k highest-replay bins."""
        if not self.bins.size:
            return np.empty(0, np.intp)
        order = np.lexsort((self.bins, -self.hits, -self.replays))
        return order[:max(int(k), 0)]

    def render(self, fmt: str = "text", top_k: int = 16) -> str:
        from repro.obs import report  # lazy: keep dataclass import-light
        return report.render(self, fmt, top_k=top_k)


def heatmap_from_stream(stream, *, label: str = "",
                        num_cores: int = 1,
                        job_class: Optional[int] = None,
                        waves_per_tile: int = 1,
                        pipeline_depth: int = 2,
                        bytes_read: float = 0.0,
                        flops: float = 0.0,
                        overhead_cycles: float = 500.0,
                        hot_degree: float = DEFAULT_HOT_DEGREE,
                        source: str = "trace",
                        meta: Optional[dict] = None) -> Heatmap:
    """Attribution from a raw committed index stream.

    Mirrors ``TraceProvider``: the trace comes from the exact
    ``trace_from_indices`` call the provider makes, so the embedded
    ``CounterSet`` and the ``wave_degree`` series are bit-identical to
    the profile path for the same stream and geometry.
    """
    stream = np.asarray(stream).reshape(-1)
    if stream.size and stream.min() < 0:
        raise ValueError("committed index stream has negative bin ids")
    if job_class is None:
        from repro.core import timing
        job_class = timing.FAO
    tr = counters_mod.trace_from_indices(
        stream, int(stream.max()) + 1 if stream.size else 1,
        num_cores=num_cores, job_class=job_class,
        waves_per_tile=waves_per_tile, pipeline_depth=pipeline_depth)
    cset = CounterSet.from_trace(
        tr, label=label, num_cores=num_cores, bytes_read=bytes_read,
        flops=flops, overhead_cycles=overhead_cycles, source=source)

    num_slots = int(stream.max()) + 1 if stream.size else 0
    if stream.size:
        idx = stream.astype(np.int64, copy=False)
        counts = np.bincount(idx, minlength=num_slots)
        bins = np.flatnonzero(counts)
        hits = counts[bins]
        # distinct (commit group, bin) pairs: every hit beyond the first
        # in its group is a serialized replay behind that bin
        group_id = np.arange(idx.size, dtype=np.int64) // COMMIT_GROUP
        uniq = np.unique(group_id * num_slots + idx)
        distinct = np.bincount(uniq % num_slots, minlength=num_slots)[bins]
        replays = hits - distinct
        # worst wave degree per bin: segment-max of each element's wave
        # degree, grouped by bin via one sort (columnar, no python loop)
        wave_id = np.minimum(np.arange(idx.size, dtype=np.int64) // LANES,
                             tr.num_waves - 1)
        elem_degree = tr.degree[wave_id]
        order = np.argsort(idx, kind="stable")
        starts = np.flatnonzero(np.diff(idx[order], prepend=-1))
        max_deg = np.maximum.reduceat(elem_degree[order], starts)
    else:
        bins = np.empty(0, np.int64)
        hits = np.empty(0, np.int64)
        replays = np.empty(0, np.int64)
        max_deg = np.empty(0, np.float64)

    return Heatmap(label=label, num_slots=num_slots, bins=bins,
                   hits=hits, replays=replays, max_wave_degree=max_deg,
                   wave_degree=np.asarray(tr.degree, np.float64),
                   counters=cset, hot_degree=float(hot_degree),
                   meta=dict(meta or {}))


def heatmap_for_spec(spec, *, hot_degree: float = DEFAULT_HOT_DEGREE) -> Heatmap:
    """Attribution for a workload spec (kernel or indices source).

    Uses ``TraceProvider.committed_stream`` so the stream, geometry, and
    counter aggregation match ``Session.profile`` on the same spec bit
    for bit.  Pre-recorded ``trace``/``run``/``hlo`` sources carry no
    index stream to attribute and raise ``ValueError``.
    """
    from repro.analysis.providers.trace import TraceProvider  # lazy: layering
    prov = TraceProvider()
    stream, job_class, wpt = prov.committed_stream(spec)
    meta = {}
    if spec.kernel is not None:
        meta = {"op": spec.kernel.op,
                "variant": spec.kernel.params.get("variant")}
    return heatmap_from_stream(
        stream, label=spec.label, num_cores=spec.num_cores,
        job_class=job_class, waves_per_tile=wpt,
        pipeline_depth=spec.pipeline_depth or 2,
        bytes_read=spec.bytes_read, flops=spec.flops,
        overhead_cycles=spec.overhead_cycles, hot_degree=hot_degree,
        source=prov.name, meta=meta)
