"""Observability layer: contention attribution + pipeline telemetry.

Two halves, both importable without jax:

* :mod:`repro.obs.heatmap` / :mod:`repro.obs.report` — per-bin,
  per-wave contention attribution from committed index streams, with
  text/json/csv renderers (``Session.heatmap``, ``repro heatmap``, and
  the service's ``heatmap`` job kind all land here);
* :mod:`repro.obs.telemetry` — the process-wide metrics registry
  (Prometheus text exposition on the service's ``GET /metrics``) and
  tracing spans with propagated trace ids.

This package sits *below* ``repro.analysis`` and ``repro.service`` in
the import graph: it depends only on ``repro.core`` and the stdlib, so
every layer above can instrument itself without cycles.
"""

from repro.obs import report, telemetry
from repro.obs.heatmap import (DEFAULT_HOT_DEGREE, Heatmap,
                               heatmap_for_spec, heatmap_from_stream)

__all__ = ["telemetry", "report", "Heatmap", "heatmap_for_spec",
           "heatmap_from_stream", "DEFAULT_HOT_DEGREE"]
