"""data subpackage."""
