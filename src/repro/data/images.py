"""Synthetic images for the paper's §4 histogram case study.

Two kinds, as in the paper: ``solid`` (monochromatic — maximum atomic
contention, e=32) and ``uniform`` (random channel values — low contention,
e~2-3).  Sizes 32 px to 4 Mpx, four 8-bit channels (RGBA)."""

from __future__ import annotations

import numpy as np

CHANNELS = 4


def make_image(kind: str, num_pixels: int, seed: int = 0,
               color: int = 128) -> np.ndarray:
    """(num_pixels, 4) uint8-valued int32 channel array."""
    if kind == "solid":
        return np.full((num_pixels, CHANNELS), color, np.int32)
    if kind == "uniform":
        rng = np.random.default_rng(seed)
        return rng.integers(0, 256, (num_pixels, CHANNELS)).astype(np.int32)
    raise ValueError(kind)


PAPER_SIZES = [2 ** p for p in range(5, 23)]  # 32 px .. 4 Mpx
