"""Deterministic synthetic data pipeline, sharded and restart-exact.

Every (step, shard) pair maps to tokens via a counter-based Philox stream,
so (a) each data shard generates only its slice (no host broadcast),
(b) restarting from a checkpoint at step ``s`` reproduces the *identical*
remaining stream — the property fault-tolerant training needs and the
tests assert, and (c) elastic rescaling re-partitions the same global
stream (global sample index = step * global_batch + position).

Tokens follow a Zipfian marginal (alpha ~1) so the embedding-gradient
scatter sees realistic frequency skew — the data-dependent contention the
paper's model prices (a monochrome "image" = constant stream; a uniform
stream = balanced histogram).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_alpha: float = 1.1     # 0 = uniform


class SyntheticLM:
    """Infinite deterministic token stream."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        if cfg.zipf_alpha > 0:
            ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
            probs = ranks ** -cfg.zipf_alpha
            self._cdf = np.cumsum(probs / probs.sum())
        else:
            self._cdf = None

    def _tokens_for(self, sample_index: np.ndarray) -> np.ndarray:
        """(n, seq_len) tokens for absolute sample indices."""
        n = sample_index.shape[0]
        out = np.empty((n, self.cfg.seq_len), np.int32)
        for row, s in enumerate(sample_index):
            rng = np.random.Generator(np.random.Philox(
                key=self.cfg.seed, counter=[0, 0, 0, int(s)]))
            u = rng.random(self.cfg.seq_len)
            if self._cdf is not None:
                out[row] = np.searchsorted(self._cdf, u).astype(np.int32)
            else:
                out[row] = (u * self.cfg.vocab_size).astype(np.int32)
        return np.clip(out, 0, self.cfg.vocab_size - 1)

    def global_batch_at(self, step: int) -> np.ndarray:
        base = step * self.cfg.global_batch
        idx = np.arange(base, base + self.cfg.global_batch)
        return self._tokens_for(idx)

    def shard_batch_at(self, step: int, shard: int, num_shards: int
                       ) -> np.ndarray:
        """This shard's rows of the step's global batch."""
        assert self.cfg.global_batch % num_shards == 0
        per = self.cfg.global_batch // num_shards
        base = step * self.cfg.global_batch + shard * per
        return self._tokens_for(np.arange(base, base + per))

    def batch_dict(self, step: int) -> dict:
        toks = self.global_batch_at(step)
        return {"tokens": toks, "labels": toks}
