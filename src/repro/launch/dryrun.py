import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (spec §MULTI-POD DRY-RUN).

For every (architecture x input shape) cell, on the single-pod 16x16 mesh
and the 2x16x16 multi-pod mesh: build the jitted step (train / prefill /
decode per shape kind), ``.lower().compile()`` against ShapeDtypeStruct
inputs, print ``memory_analysis()`` / ``cost_analysis()``, parse the
collective traffic from the compiled HLO, and emit the roofline terms to
``results/dryrun/<arch>__<shape>__<mesh>.json``.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]
"""

import argparse
import json
import time
import traceback

from repro.configs import ARCHS, SHAPES, get_config, shape_applicable
from repro.core import hlo as hlo_mod
from repro.core import roofline
from repro.launch.lowering import (OPTIMIZATIONS, build_lowered,  # noqa: F401
                                   shape_tuned_config)
from repro.launch.mesh import make_production_mesh, mesh_chips

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               variant: str = "base"):
    cfg0 = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg0, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "pod2" if multi_pod else "single",
                "status": "skipped", "reason": why}
    cfg, loss_chunk, train_kw = shape_tuned_config(cfg0, shape, variant)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh_chips(mesh)
    mesh_name = "pod2" if multi_pod else "single"
    tokens_per_step = shape.global_batch * (
        shape.seq_len if shape.kind != "decode" else 1)
    n_params = cfg.param_count()
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        model_flops = 6.0 * n_active * tokens_per_step
    else:
        model_flops = 2.0 * n_active * tokens_per_step

    t0 = time.time()
    lowered = build_lowered(cfg, shape, mesh, loss_chunk=loss_chunk,
                            train_kw=train_kw)
    compiled = lowered.compile()
    compile_s = time.time() - t0

    mem = hlo_mod.memory_analysis_dict(compiled)
    cost = hlo_mod.cost_analysis_dict(compiled)
    terms = roofline.from_compiled(
        compiled, arch=arch, shape=shape_name, mesh_name=mesh_name,
        chips=chips, model_flops=model_flops)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "ok", "chips": chips,
        "compile_seconds": round(compile_s, 1),
        "params": n_params, "active_params": n_active,
        "tokens_per_step": tokens_per_step,
        "memory_analysis": mem,
        "cost_flops": cost.get("flops"),
        "cost_bytes": cost.get("bytes accessed"),
        "roofline": terms.as_dict(),
    }
    return rec


def cell_path(arch, shape_name, mesh_name, variant="base"):
    safe = arch.replace("/", "_")
    suffix = "" if variant == "base" else f"__{variant}"
    return os.path.join(RESULTS_DIR,
                        f"{safe}__{shape_name}__{mesh_name}{suffix}.json")


def run_and_save(arch, shape_name, multi_pod, force=False,
                 variant="base") -> dict:
    mesh_name = "pod2" if multi_pod else "single"
    path = cell_path(arch, shape_name, mesh_name, variant)
    if not force and os.path.exists(path):
        with open(path) as f:
            cached = json.load(f)
        if cached.get("status") in ("ok", "skipped"):
            return cached  # only errors are retried
    try:
        rec = lower_cell(arch, shape_name, multi_pod, variant)
    except Exception as e:  # a failing cell is a bug; record it loudly
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "error", "error": repr(e),
               "traceback": traceback.format_exc()[-4000:]}
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    status = rec["status"]
    extra = ""
    if status == "ok":
        r = rec["roofline"]
        extra = (f" dominant={r['dominant']} useful={r['useful_ratio']:.2f}"
                 f" compile={rec['compile_seconds']}s")
    elif status == "error":
        extra = " " + rec["error"][:120]
    print(f"[dryrun] {arch} x {shape_name} x {mesh_name} ({variant}): "
          f"{status}{extra}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "pod2", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="base", choices=["base", "opt"])
    args = ap.parse_args()

    meshes = {"single": [False], "pod2": [True], "both": [False, True]}[
        args.mesh]
    archs = list(ARCHS) if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    n_bad = 0
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                rec = run_and_save(arch, shape_name, mp, force=args.force,
                                   variant=args.variant)
                n_bad += rec["status"] == "error"
    print(f"[dryrun] done; {n_bad} errors")
    raise SystemExit(1 if n_bad else 0)


if __name__ == "__main__":
    main()
