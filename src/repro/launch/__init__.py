"""launch subpackage."""
