"""Serving driver: batched prefill + autoregressive decode on local devices.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b --reduced \
      --batch 4 --prompt-len 16 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.registry import build_model, make_batch
from repro.serve import step as serve_mod


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    stub = make_batch(cfg, args.batch, args.prompt_len)
    prompt = stub["tokens"]
    extras = {k: v for k, v in stub.items()
              if k in ("frames", "image_embeds")}
    scfg = serve_mod.ServeConfig(temperature=args.temperature,
                                 max_len=args.prompt_len + args.gen)
    t0 = time.time()
    out = serve_mod.generate(model, params, prompt, args.gen, scfg,
                             extras=extras, rng=jax.random.PRNGKey(1))
    dt = time.time() - t0
    total_new = args.batch * args.gen
    print(f"[serve] {args.arch}: generated {out.shape} in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s incl. prompt replay)")
    assert out.shape == (args.batch, args.prompt_len + args.gen)
    assert bool(jnp.all(out >= 0)) and bool(jnp.all(out < cfg.vocab_size + 256))
    return out


if __name__ == "__main__":
    main()
