"""ShapeDtypeStruct stand-ins for every model input (spec §dry-run step 2).

Weak-type-correct, shardable, no device allocation.  ``input_specs``
returns the batch for a training step or the (cache, tokens, pos) set for
a serving step, with NamedShardings attached for the given mesh.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import data_axes_of
from repro.parallel import sharding as shd


def _batch_axes(mesh, batch_size: int) -> Optional[tuple[str, ...]]:
    """Data axes if the batch divides across them, else replicate."""
    axes = data_axes_of(mesh)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return axes if batch_size % n == 0 and batch_size >= n else None


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh) -> dict:
    b, s = shape.global_batch, shape.seq_len
    axes = _batch_axes(mesh, b)
    bspec = P(axes) if axes else P()

    def sds(shp, dt, spec):
        return jax.ShapeDtypeStruct(shp, dt,
                                    sharding=NamedSharding(mesh, spec))

    batch = {
        "tokens": sds((b, s), jnp.int32, P(axes)),
        "labels": sds((b, s), jnp.int32, P(axes)),
    }
    if cfg.family == "audio":
        batch["frames"] = sds((b, cfg.encoder_frames, cfg.d_model),
                              jnp.dtype(cfg.dtype), P(axes, None, None))
    if cfg.family == "vlm":
        batch["image_embeds"] = sds((b, cfg.image_tokens, cfg.d_model),
                                    jnp.dtype(cfg.dtype), P(axes, None, None))
    del bspec
    return batch


def param_specs(model, mesh, rng=None) -> tuple:
    """(ShapeDtypeStruct tree with shardings, sharding tree)."""
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    shapes = jax.eval_shape(model.init, rng)
    shardings = shd.param_shardings(shapes, model.cfg, mesh)
    with_sh = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, shardings)
    return with_sh, shardings


def state_specs(model, mesh, ocfg=None) -> tuple:
    """Full train state (params + AdamW state) specs/shardings."""
    from repro.train import step as train_step_mod
    rng = jax.random.PRNGKey(0)
    shapes = jax.eval_shape(
        lambda r: train_step_mod.init_state(model, r), rng)
    pspecs = shd.param_pspecs(shapes["params"], model.cfg)
    state_pspecs = {
        "params": pspecs,
        "opt": {"m": pspecs, "v": pspecs, "master": pspecs, "count": P()},
        "step": P(),
    }
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), state_pspecs,
                             is_leaf=lambda x: isinstance(x, P))
    with_sh = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, shardings)
    return with_sh, shardings


def decode_specs(cfg: ModelConfig, shape: ShapeConfig, model, mesh,
                 params_sds) -> tuple:
    """(cache SDS tree, cache shardings, tokens SDS, pos SDS)."""
    b = shape.global_batch
    axes = _batch_axes(mesh, b)
    extras = {}
    if cfg.family == "audio":
        extras["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_frames, cfg.d_model), jnp.dtype(cfg.dtype),
            sharding=NamedSharding(mesh, P(axes, None, None)))
    if cfg.family == "vlm":
        extras["image_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.image_tokens, cfg.d_model), jnp.dtype(cfg.dtype),
            sharding=NamedSharding(mesh, P(axes, None, None)))

    def cache_shape_fn(params, **ex):
        if cfg.family == "audio":
            return model.init_cache(params, b, shape.seq_len,
                                    frames=ex["frames"])
        if cfg.family == "vlm":
            return model.init_cache(params, b, shape.seq_len,
                                    image_embeds=ex["image_embeds"])
        return model.init_cache(params, b, shape.seq_len)

    cache_shapes = jax.eval_shape(cache_shape_fn, params_sds, **extras)
    cache_pspecs = shd.cache_pspecs(cache_shapes, cfg, data_axes=axes,
                                    seq_axis="model")
    cache_sh = jax.tree.map(
        lambda s: NamedSharding(mesh, s), cache_pspecs,
        is_leaf=lambda x: isinstance(x, P))
    cache_sds = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        cache_shapes, cache_sh)
    tokens = jax.ShapeDtypeStruct(
        (b, 1), jnp.int32, sharding=NamedSharding(mesh, P(axes, None)))
    pos = jax.ShapeDtypeStruct((), jnp.int32,
                               sharding=NamedSharding(mesh, P()))
    return cache_sds, cache_sh, tokens, pos


def prefill_specs(cfg: ModelConfig, shape: ShapeConfig, mesh) -> dict:
    b, s = shape.global_batch, shape.seq_len
    axes = _batch_axes(mesh, b)
    out = {"tokens": jax.ShapeDtypeStruct(
        (b, s), jnp.int32, sharding=NamedSharding(mesh, P(axes, None)))}
    if cfg.family == "audio":
        out["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_frames, cfg.d_model), jnp.dtype(cfg.dtype),
            sharding=NamedSharding(mesh, P(axes, None, None)))
    if cfg.family == "vlm":
        out["image_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.image_tokens, cfg.d_model), jnp.dtype(cfg.dtype),
            sharding=NamedSharding(mesh, P(axes, None, None)))
    return out
