"""Shared step-lowering helpers for the dry-run grid and the static audit.

``build_lowered`` packages the (config x shape) -> jitted-step -> ``.lower()``
plumbing that used to live inline in ``launch/dryrun.py``: build the
train / prefill / decode step for a shape kind, wire the ShapeDtypeStruct
inputs and shardings from ``launch/specs.py``, and lower under the given
mesh.  The dry-run grid compiles the result; ``repro.audit`` stops at the
*pre-optimization* HLO, where ``scatter`` / ``dynamic-update-slice`` idioms
are still visible (post-optimization CPU HLO rewrites scatters into
``while`` loops).

Unlike ``dryrun``, importing this module does NOT mutate ``XLA_FLAGS``:
pre-optimization HLO is pre-SPMD (global shapes), so audits run on a tiny
mesh with no host-device-count override.
"""

from __future__ import annotations

import dataclasses

import jax

from repro.launch import specs as specs_mod
from repro.launch.mesh import data_axes_of
from repro.models.registry import build_model
from repro.optim import adamw
from repro.parallel import ctx as pctx
from repro.serve import step as serve_mod
from repro.train import step as train_mod

# ---------------------------------------------------------------------------
# §Perf hillclimb variants: per (arch, shape) config overrides, applied on
# top of the baseline.  Keys match EXPERIMENTS.md §Perf iteration ids.
# ---------------------------------------------------------------------------
OPTIMIZATIONS: dict[tuple[str, str], dict] = {
    ("command-r-plus-104b", "train_4k"): dict(
        attn_tp_expand=True, train_constrain_grad_sharding=True,
        attn_bf16_score_grad=True),
    ("gemma2-27b", "train_4k"): dict(
        attn_tp_expand=True, train_constrain_grad_sharding=True,
        attn_bf16_score_grad=True),
    ("qwen3-moe-235b-a22b", "train_4k"): dict(
        attn_tp_expand=True, train_constrain_grad_sharding=True,
        moe_bf16_combine=True),
}


def shape_tuned_config(cfg, shape, variant: str = "base"):
    """Per-shape impl knobs (documented in EXPERIMENTS.md §Dry-run)."""
    kw = {}
    if shape.kind == "prefill" and shape.seq_len >= 32768 \
            and not cfg.rwkv and cfg.family != "ssm":
        kw["attn_impl"] = "blockwise"
        kw["kv_block"] = 1024
    if cfg.vocab_size >= 100_000 and shape.kind == "train":
        kw["loss_chunk"] = 455  # divides 4095; keeps f32 logits ~0.5 GiB/dev
    if variant == "opt":
        kw.update(OPTIMIZATIONS.get((cfg.name, shape.name), {}))
    loss_chunk = kw.pop("loss_chunk", 0)
    train_kw = {k[len("train_"):]: kw.pop(k) for k in list(kw)
                if k.startswith("train_")}
    return dataclasses.replace(cfg, **kw) if kw else cfg, loss_chunk, train_kw


def build_lowered(cfg, shape, mesh, *, loss_chunk: int = 0,
                  train_kw: dict | None = None):
    """Lower the step for ``shape.kind`` under ``mesh``; returns jax Lowered.

    ``cfg`` must already carry any shape-tuned overrides (see
    ``shape_tuned_config``).
    """
    daxes = data_axes_of(mesh)
    model = build_model(cfg)
    with pctx.use_mesh(mesh, data_axes=daxes, tp_axis="model"):
        if shape.kind == "train":
            num_data = 1
            for a in daxes:
                num_data *= mesh.shape[a]
            accum = max(1, shape.global_batch // num_data)
            tcfg = train_mod.TrainConfig(accum_steps=accum,
                                         loss_chunk=loss_chunk,
                                         **(train_kw or {}))
            ocfg = adamw.AdamWConfig()
            step_fn = train_mod.make_train_step(model, tcfg, ocfg)
            state_sds, state_sh = specs_mod.state_specs(model, mesh)
            batch = specs_mod.train_batch_specs(cfg, shape, mesh)
            return jax.jit(
                step_fn,
                in_shardings=(state_sh,
                              jax.tree.map(lambda s: s.sharding, batch)),
                donate_argnums=(0,),
            ).lower(state_sds, batch)
        if shape.kind == "prefill":
            scfg = serve_mod.ServeConfig(max_len=shape.seq_len)
            prefill = serve_mod.make_prefill(model, scfg)
            params_sds, params_sh = specs_mod.param_specs(model, mesh)
            inputs = specs_mod.prefill_specs(cfg, shape, mesh)
            tokens = inputs.pop("tokens")
            extras = inputs or None
            return jax.jit(
                prefill, in_shardings=(params_sh, tokens.sharding, None),
                static_argnums=(),
            ).lower(params_sds, tokens, extras)
        # decode
        decode = serve_mod.make_decode_step(model)
        params_sds, params_sh = specs_mod.param_specs(model, mesh)
        cache_sds, cache_sh, tokens, pos = specs_mod.decode_specs(
            cfg, shape, model, mesh, params_sds)
        return jax.jit(
            decode,
            in_shardings=(params_sh, cache_sh, tokens.sharding, pos.sharding),
            donate_argnums=(1,),
        ).lower(params_sds, cache_sds, tokens, pos)


def pre_optimization_hlo(lowered) -> str:
    """Pre-optimization HLO text of a jax Lowered (scatters intact)."""
    try:
        ir = lowered.compiler_ir(dialect="hlo")
        return ir.as_hlo_text()
    except Exception:
        # Older/newer jax: fall back to whatever textual IR is available.
        return lowered.as_text()
