"""End-to-end training driver with checkpoint/restart fault tolerance.

Runs a reduced (or full) config for N steps on whatever devices exist:

  PYTHONPATH=src python -m repro.launch.train --arch granite-moe-1b-a400m \
      --reduced --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt \
      --save-every 10 [--simulate-failure-at 17]

The loop exercises the production path end to end: deterministic sharded
data pipeline, jitted train step, async checkpointing with atomic commit,
failure injection + restore-from-latest (data stream replay is exact), and
straggler reports from the queue-model detector.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.registry import build_model, make_batch
from repro.optim import adamw
from repro.runtime import fault_tolerance as ft
from repro.runtime import stragglers
from repro.train import step as train_mod


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=10)
    ap.add_argument("--simulate-failure-at", type=int, default=None)
    ap.add_argument("--accum", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)

    ocfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=5,
                             total_steps=args.steps)
    tcfg = train_mod.TrainConfig(accum_steps=args.accum)
    # no donation here: at init m/v are identical zero buffers which XLA
    # may alias, and donating the same buffer twice is an error; the
    # production (dry-run) path donates sharded state safely
    step_fn = jax.jit(train_mod.make_train_step(model, tcfg, ocfg))

    state = train_mod.init_state(model, jax.random.PRNGKey(0))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=args.seq,
                                  global_batch=args.batch))
    ckpt = store.AsyncCheckpointer(args.ckpt_dir)
    coord = ft.Coordinator(num_hosts=4)
    injector = None
    if args.simulate_failure_at is not None:
        injector = ft.FailureInjector({args.simulate_failure_at: 1})

    # modality stubs are deterministic per step
    def batch_for(step: int) -> dict:
        b = data.batch_dict(step)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        if cfg.family in ("audio", "vlm"):
            stub = make_batch(cfg, args.batch, args.seq,
                              rng=jax.random.PRNGKey(step))
            b.update({k: v for k, v in stub.items()
                      if k in ("frames", "image_embeds")})
        return b

    losses = {}
    state_box = {"state": state}

    def train_one_step(step: int) -> dict:
        t0 = time.time()
        new_state, metrics = step_fn(state_box["state"], batch_for(step))
        loss = float(metrics["xent"])
        state_box["state"] = new_state
        losses[step] = loss
        return {"xent": loss, "step_time_s": time.time() - t0}

    def save_fn(step: int) -> None:
        ckpt.submit(step, state_box["state"])

    def restore_fn() -> int:
        ckpt.wait()
        restored, step = store.restore(args.ckpt_dir, state_box["state"])
        state_box["state"] = restored
        print(f"[train] restored from checkpoint at step {step}")
        return step

    out = ft.run_with_restarts(
        num_steps=args.steps, train_one_step=train_one_step,
        save_every=args.save_every, save_fn=save_fn, restore_fn=restore_fn,
        coordinator=coord, injector=injector)
    ckpt.close()

    hist = out["history"]
    first, last = hist[0]["xent"], hist[-1]["xent"]
    print(f"[train] {args.arch}: steps={len(hist)} restarts={out['restarts']}"
          f" loss {first:.3f} -> {last:.3f}")
    reports = stragglers.detect(
        {h.host_id: h.step_times for h in coord.hosts.values()})
    for r in reports:
        flag = " STRAGGLER" if r.is_straggler else ""
        print(f"[train] host {r.host_id}: mean {r.mean_step_s:.3f}s "
              f"barrier-U {r.barrier_utilization:.2f}{flag}")
    if not (np.isfinite(last) and last < first):
        raise SystemExit("loss did not improve")
    return out


if __name__ == "__main__":
    main()
