"""Production mesh construction (spec §MULTI-POD DRY-RUN).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state; callers (dryrun/train/serve) control
``XLA_FLAGS`` before first jax init.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh over whatever local devices exist (tests)."""
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def data_axes_of(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def mesh_chips(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
