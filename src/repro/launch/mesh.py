"""Production mesh construction (spec §MULTI-POD DRY-RUN).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state; callers (dryrun/train/serve) control
``XLA_FLAGS`` before first jax init.
"""

from __future__ import annotations

import jax


def compat_make_mesh(shape, axes):
    """``jax.make_mesh`` across jax versions.

    ``axis_types`` (and ``jax.sharding.AxisType``) only exist on newer
    jax; older versions default every axis to Auto, which is exactly what
    we request, so omitting the kwarg there is behavior-identical.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh over whatever local devices exist (tests)."""
    return compat_make_mesh(shape, axes)


def data_axes_of(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def mesh_chips(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
