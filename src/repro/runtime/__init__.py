"""runtime subpackage."""
