"""Straggler detection via the paper's operational method.

Each host is a single-server queue whose jobs are training steps.  From
per-host step times we form the operational utilization of the *fleet
barrier*: a host whose service time drifts above the fleet's median
(utilization of the barrier interval > threshold) is flagged.  This reuses
the same law (U = B/T, B = N*S) the shared-scatter model uses — paper §6:
"our method is also applicable to other functional units".
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass
class StragglerReport:
    host_id: int
    mean_step_s: float
    barrier_utilization: float   # host busy time / barrier window
    is_straggler: bool


def detect(step_times_per_host: dict[int, Sequence[float]],
           window: int = 20, threshold: float = 1.15
           ) -> list[StragglerReport]:
    """threshold: flagged when host busy time exceeds 115% of the fleet
    median busy time over the window (i.e. it sets the barrier)."""
    reports = []
    recent = {h: np.asarray(list(t)[-window:], np.float64)
              for h, t in step_times_per_host.items() if len(t)}
    if not recent:
        return reports
    med = np.median([t.mean() for t in recent.values()])
    barrier = max(t.mean() for t in recent.values())
    for host, t in sorted(recent.items()):
        busy = t.mean()
        u = busy / barrier if barrier > 0 else 0.0
        reports.append(StragglerReport(
            host_id=host, mean_step_s=float(busy),
            barrier_utilization=float(u),
            is_straggler=bool(busy > threshold * med)))
    return reports


def mitigation(report: list[StragglerReport]) -> str:
    bad = [r.host_id for r in report if r.is_straggler]
    if not bad:
        return "none"
    return (f"hosts {bad} set the barrier: exclude from the next elastic "
            f"remesh epoch, or rebalance their data shards")
