"""Fault-tolerant step loop: heartbeats, failure detection, checkpoint-
restart, and elastic rescale planning.

Single-process simulation of the multi-host control plane (no real fleet
in this container): hosts are modeled objects that beat every step; the
coordinator detects missed beats / injected failures and drives the same
recovery path a real deployment would — restore-from-latest + data-stream
resume (exact, thanks to the counter-based pipeline) + optional remesh.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import numpy as np


@dataclasses.dataclass
class HostState:
    host_id: int
    last_beat: float = 0.0
    alive: bool = True
    step_times: list = dataclasses.field(default_factory=list)


class Coordinator:
    """Heartbeat registry + failure detector + restart counter."""

    def __init__(self, num_hosts: int, timeout_s: float = 5.0):
        self.hosts = {i: HostState(i) for i in range(num_hosts)}
        self.timeout_s = timeout_s
        self.restarts = 0

    def beat(self, host_id: int, step_time_s: Optional[float] = None,
             now: Optional[float] = None) -> None:
        h = self.hosts[host_id]
        h.last_beat = now if now is not None else time.monotonic()
        if step_time_s is not None:
            h.step_times.append(step_time_s)

    def fail(self, host_id: int) -> None:
        self.hosts[host_id].alive = False

    def dead_hosts(self, now: Optional[float] = None) -> list[int]:
        now = now if now is not None else time.monotonic()
        return [h.host_id for h in self.hosts.values()
                if not h.alive or (h.last_beat and
                                   now - h.last_beat > self.timeout_s)]

    def healthy(self, now: Optional[float] = None) -> bool:
        return not self.dead_hosts(now)


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    old_shape: tuple
    new_shape: tuple
    action: str                    # "shrink" | "grow" | "none"

    @property
    def changed(self) -> bool:
        return self.old_shape != self.new_shape


def plan_remesh(old_shape: tuple, axes: tuple, available_devices: int
                ) -> ElasticPlan:
    """Shrink/grow the leading (pod/data) axis to fit available devices.

    Keeps the model axis intact (TP degree is a property of the model
    sharding); scales data parallelism, which the checkpoint format and
    the counter-based data stream both tolerate exactly.
    """
    total = int(np.prod(old_shape))
    if available_devices >= total:
        return ElasticPlan(old_shape, old_shape, "none")
    lead = old_shape[0]
    rest = total // lead
    new_lead = max(1, available_devices // rest)
    new_shape = (new_lead,) + tuple(old_shape[1:])
    return ElasticPlan(old_shape, new_shape, "shrink")


class FailureInjector:
    """Deterministic failure schedule for tests/examples."""

    def __init__(self, fail_at_steps: dict[int, int]):
        # {step: host_id}
        self.fail_at_steps = dict(fail_at_steps)

    def maybe_fail(self, step: int, coordinator: Coordinator) -> Optional[int]:
        host = self.fail_at_steps.pop(step, None)
        if host is not None:
            coordinator.fail(host)
        return host


def run_with_restarts(
    *,
    num_steps: int,
    train_one_step: Callable[[int], dict],
    save_every: int,
    save_fn: Callable[[int], None],
    restore_fn: Callable[[], int],
    coordinator: Coordinator,
    injector: Optional[FailureInjector] = None,
    max_restarts: int = 8,
) -> dict:
    """Drive the step loop with checkpoint/restart semantics.

    ``train_one_step(step)`` runs the jitted step and returns metrics;
    ``restore_fn()`` reloads the latest checkpoint and returns its step.
    On detected failure: mark restart, restore, resume from the restored
    step (the data pipeline is keyed by step, so the replay is exact).
    """
    step = 0
    history = []
    while step < num_steps:
        if injector is not None:
            failed = injector.maybe_fail(step, coordinator)
            if failed is not None:
                if coordinator.restarts >= max_restarts:
                    raise RuntimeError("restart budget exhausted")
                coordinator.restarts += 1
                # recovery: replace host (simulated) + restore
                coordinator.hosts[failed].alive = True
                step = restore_fn()
                continue
        metrics = train_one_step(step)
        for h in coordinator.hosts.values():
            coordinator.beat(h.host_id,
                             step_time_s=metrics.get("step_time_s"))
        history.append({"step": step, **{k: float(v)
                                         for k, v in metrics.items()}})
        step += 1
        if step % save_every == 0:
            save_fn(step)
    return {"history": history, "restarts": coordinator.restarts}
