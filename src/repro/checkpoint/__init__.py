"""checkpoint subpackage."""
