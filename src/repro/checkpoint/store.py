"""Sharded checkpointing with atomic commit, async writes, and elastic
resharding on restore.

Layout:  <dir>/step_<N>/
            manifest.json        tree structure, shapes, dtypes, step
            <leaf-id>.npy        one file per leaf (gathered host values)
         <dir>/LATEST            committed step pointer (atomic rename)

Restore maps every leaf onto the *current* mesh via ``jax.device_put``
with the caller's shardings — the checkpoint format is mesh-shape
agnostic, which is what elastic rescaling (growing/shrinking the pod axis)
requires.  A background thread handles serialization off the training
loop; commit order (leaves -> manifest -> LATEST) guarantees a torn write
is never visible.
"""

from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

# numpy cannot save/cast extension dtypes (bfloat16 etc.); store them as
# same-width unsigned ints and reconstruct from the manifest dtype.
_EXT_DTYPES = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
    "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8),
}


def _to_savable(arr: np.ndarray) -> np.ndarray:
    name = str(arr.dtype)
    if name in _EXT_DTYPES:
        return arr.view(_EXT_DTYPES[name][1])
    return arr


def _from_saved(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _EXT_DTYPES:
        return arr.view(_EXT_DTYPES[dtype_name][0])
    return arr


def _flatten_with_ids(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef, [f"leaf_{i:05d}" for i in range(len(leaves))]


def save(directory: str, step: int, tree: Any) -> str:
    """Synchronous checkpoint write with atomic commit."""
    tmp = os.path.join(directory, f".tmp_step_{step}")
    final = os.path.join(directory, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef, ids = _flatten_with_ids(tree)
    manifest = {"step": step, "leaves": []}
    for lid, leaf in zip(ids, leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, lid + ".npy"), _to_savable(arr))
        manifest["leaves"].append(
            {"id": lid, "shape": list(arr.shape), "dtype": str(arr.dtype)})
    manifest["treedef"] = jax.tree_util.tree_structure(tree).serialize_using_proto().hex() \
        if hasattr(jax.tree_util.tree_structure(tree), "serialize_using_proto") else None
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    with open(os.path.join(directory, ".LATEST_tmp"), "w") as f:
        f.write(str(step))
    os.replace(os.path.join(directory, ".LATEST_tmp"),
               os.path.join(directory, "LATEST"))
    return final


def latest_step(directory: str) -> Optional[int]:
    path = os.path.join(directory, "LATEST")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return int(f.read().strip())


def restore(directory: str, like: Any, step: Optional[int] = None,
            shardings: Any = None) -> tuple[Any, int]:
    """Restore into the structure of ``like``; reshard onto ``shardings``
    (a matching tree of NamedSharding) when given — works across mesh
    shapes (elastic)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    d = os.path.join(directory, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    dtypes = {m["id"]: m["dtype"] for m in manifest["leaves"]}
    leaves, treedef, ids = _flatten_with_ids(like)
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves))
    out = []
    for lid, leaf, sh in zip(ids, leaves, shard_leaves):
        arr = _from_saved(np.load(os.path.join(d, lid + ".npy")),
                          dtypes.get(lid, ""))
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out), step


def gc(directory: str, keep: int = 3) -> None:
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(directory)
        if n.startswith("step_"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s}"), ignore_errors=True)


class AsyncCheckpointer:
    """Background writer: ``submit`` returns immediately; ``wait`` joins."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._q: queue.Queue = queue.Queue()
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, host_tree = item
            try:
                save(self.directory, step, host_tree)
                gc(self.directory, self.keep)
            except BaseException as e:  # surfaced on next submit/wait
                self._err = e
            finally:
                self._q.task_done()

    def submit(self, step: int, tree: Any) -> None:
        if self._err:
            raise self._err
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)
        self._q.put((int(step), host_tree))

    def wait(self) -> None:
        self._q.join()
        if self._err:
            raise self._err

    def close(self) -> None:
        self.wait()
        self._q.put(None)
        self._thread.join()
