"""Declarative workload-transform catalog for the optimization advisor.

Each ``Transform`` rewrites a ``WorkloadSpec`` into a semantically
equivalent launch with different contention/occupancy characteristics —
*without touching any kernel code*.  The catalog covers the
contention-reducing families Schweizer et al. measure as having large,
predictable effects, mapped onto this repo's workload sources:

    rotation      per-lane channel rotation (the paper-§5 ``hist2``
                  trick: commit-group lanes hit different bins) — for
                  histogram kernel specs
    replication   bin privatization: each destination splits into R
                  replicas picked round-robin by stream position, at the
                  cost of R× scratch and a final cross-replica reduce —
                  for raw index streams
    substitution  CAS-class read-modify-verify loops replaced by
                  FAO-class accumulate (job-class substitution)
    geometry      launch-shape changes (``waves_per_tile`` /
                  ``pipeline_depth``) that move the occupancy estimate
                  the queue model runs at
    remap         strided interleave of the index stream so clustered
                  duplicates spread across commit groups

A transform is three judgements plus bookkeeping: ``legal(spec)`` (can
this rewrite apply, judged from the spec alone), ``apply(spec)`` (the
rewritten, relabeled spec), and ``cost(spec)`` (what the rewrite spends:
extra scratch bytes, extra reduce work).  ``apply`` never mutates —
specs are frozen, so every rewrite derives via ``with_``.

A deliberate omission: FAO→POPC substitution (dropping the atomic's
result read) predicts the largest speedups of all, but its legality —
"no later instruction reads the accumulated value" — is a property of
the surrounding program, not of the ``WorkloadSpec``, so the default
catalog does not offer it.  Register a custom transform if your kernel
qualifies.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.analysis.workload import KernelSource, WorkloadSpec
from repro.core import timing
from repro.core.counters import COMMIT_GROUP


@dataclasses.dataclass(frozen=True)
class TransformCost:
    """What a rewrite spends to buy its contention reduction."""

    scratch_bytes: float = 0.0     # extra VMEM scratch (e.g. bin replicas)
    reduce_flops: float = 0.0      # extra post-pass reduction work
    note: str = ""                 # human-readable caveat

    @staticmethod
    def merge(costs: Sequence["TransformCost"]) -> "TransformCost":
        notes = [c.note for c in costs if c.note]
        return TransformCost(
            scratch_bytes=float(sum(c.scratch_bytes for c in costs)),
            reduce_flops=float(sum(c.reduce_flops for c in costs)),
            note="; ".join(notes))


class Transform:
    """One declarative spec rewrite (see module docstring).

    Subclasses set ``name`` (unique within a catalog; shows up in
    candidate labels) and ``family`` (the search composes at most one
    transform per family), and implement ``legal``/``apply``; ``cost``
    and ``params`` default to free/empty.
    """

    name: str = ""
    family: str = ""

    def legal(self, spec: WorkloadSpec) -> bool:
        raise NotImplementedError

    def apply(self, spec: WorkloadSpec) -> WorkloadSpec:
        raise NotImplementedError

    def cost(self, spec: WorkloadSpec) -> TransformCost:
        del spec
        return TransformCost()

    def params(self) -> dict:
        """The transform's own parameters (flat, report-friendly)."""
        return {}

    def _relabel(self, spec: WorkloadSpec) -> str:
        return f"{spec.label}+{self.name}"

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self.name})"


class ChannelRotation(Transform):
    """The paper-§5 ``hist2`` rewrite: per-lane channel rotation.

    Lanes of one commit group read *different* channels, so a
    monochromatic tile's 32 identical bin updates become updates to (up
    to) ``channels`` distinct padded bins — the bin/channel-padding
    family.  Pure index arithmetic inside the kernel: no scratch, no
    extra reduce (the per-channel sub-histograms already exist).
    """

    name = "rotate-channels"
    family = "rotation"

    def legal(self, spec: WorkloadSpec) -> bool:
        return (spec.kernel is not None
                and spec.kernel.op == "histogram"
                and spec.kernel.params.get("variant") == "hist")

    def apply(self, spec: WorkloadSpec) -> WorkloadSpec:
        params = dict(spec.kernel.params, variant="hist2")
        return spec.with_(kernel=KernelSource(op="histogram", params=params),
                          label=self._relabel(spec))

    def cost(self, spec: WorkloadSpec) -> TransformCost:
        return TransformCost(
            note="per-lane channel rotation (hist2): index arithmetic only")


class Replicate(Transform):
    """Bin privatization: split each destination into ``factor`` replicas.

    Stream position picks the replica round-robin, so duplicates inside
    a commit group spread across ``factor`` distinct bins (e drops by up
    to ``factor``).  Costs ``factor``× the bin storage and a final
    reduce across replicas.
    """

    family = "replication"

    def __init__(self, factor: int) -> None:
        if factor < 2:
            raise ValueError(f"replication factor must be >= 2, got {factor}")
        self.factor = int(factor)
        self.name = f"replicate-x{self.factor}"

    def legal(self, spec: WorkloadSpec) -> bool:
        return spec.indices is not None

    def apply(self, spec: WorkloadSpec) -> WorkloadSpec:
        idx = np.asarray(spec.indices).reshape(-1)
        replica = np.arange(idx.size, dtype=idx.dtype) % self.factor
        return spec.with_(indices=idx * self.factor + replica,
                          num_bins=spec.num_bins * self.factor,
                          label=self._relabel(spec))

    def cost(self, spec: WorkloadSpec) -> TransformCost:
        return TransformCost(
            scratch_bytes=float(spec.num_bins * (self.factor - 1) * 4),
            reduce_flops=float(spec.num_bins * self.factor),
            note=f"{self.factor} bin replicas need a final cross-replica "
                 f"reduce")

    def params(self) -> dict:
        return {"factor": self.factor}


class CasToFao(Transform):
    """Job-class substitution: CAS-class retry loops become FAO jobs.

    Schweizer et al.'s op substitution: a read-modify-verify loop (f32
    accumulate lowered to compare-and-swap) replaced by a plain
    fetch-and-op, legal when the accumulation can be reassociated or
    carried in fixed point.  Applies to raw index streams tagged CAS, to
    scatter-add kernel launches with a CAS job class, and to *weighted*
    histograms (whose f32 weight accumulation is the CAS case).
    """

    name = "cas-to-fao"
    family = "substitution"

    def legal(self, spec: WorkloadSpec) -> bool:
        if spec.indices is not None:
            return spec.job_class == timing.CAS
        if spec.kernel is not None:
            if spec.kernel.op == "scatter_add":
                return spec.kernel.params.get("job_class") == timing.CAS
            if spec.kernel.op == "histogram":
                return bool(spec.kernel.params.get("weighted"))
        return False

    def apply(self, spec: WorkloadSpec) -> WorkloadSpec:
        label = self._relabel(spec)
        if spec.indices is not None:
            return spec.with_(job_class=timing.FAO, label=label)
        if spec.kernel.op == "scatter_add":
            params = dict(spec.kernel.params, job_class=timing.FAO)
            return spec.with_(
                kernel=KernelSource(op="scatter_add", params=params),
                label=label)
        params = dict(spec.kernel.params, weighted=False, force_fao=True)
        return spec.with_(kernel=KernelSource(op="histogram", params=params),
                          label=label)

    def cost(self, spec: WorkloadSpec) -> TransformCost:
        return TransformCost(
            note="needs a reassociable / fixed-point accumulation in place "
                 "of the CAS retry loop")


def _effective_waves_per_tile(spec: WorkloadSpec) -> Optional[int]:
    """What the acquisition path will resolve an unset geometry to.

    Mirrors the providers' defaulting per source family; ``None`` means
    "not resolvable from the spec" (opaque ``run`` callables).
    """
    if spec.waves_per_tile is not None:
        return spec.waves_per_tile
    if spec.trace is not None:
        return spec.trace.waves_per_tile
    if spec.kernel is not None:
        if spec.kernel.op == "histogram":
            from repro.kernels.histogram import ops as hist_ops  # lazy: jax
            return hist_ops.default_waves_per_tile(
                spec.kernel.params["img"])
        if spec.kernel.op == "scatter_add":
            from repro.kernels.scatter_add import ops as scat_ops  # lazy
            return scat_ops.default_waves_per_tile()
    if spec.indices is not None:
        return 1     # trace_from_indices' ``waves_per_tile or 1``
    return None


class SetWavesPerTile(Transform):
    """Launch-geometry rewrite: issue ``waves_per_tile`` waves per tile."""

    family = "geometry"

    def __init__(self, waves_per_tile: int) -> None:
        self.waves_per_tile = int(waves_per_tile)
        self.name = f"wpt={self.waves_per_tile}"

    def legal(self, spec: WorkloadSpec) -> bool:
        # compare the *effective* value (like SetPipelineDepth): an unset
        # field resolves to a source-family default at collection time,
        # and re-stating that default would enumerate a no-op candidate
        # whose fingerprint (None vs N) even defeats dedup
        if spec.compiled is not None or spec.hlo_text is not None:
            return False
        return _effective_waves_per_tile(spec) != self.waves_per_tile

    def apply(self, spec: WorkloadSpec) -> WorkloadSpec:
        return spec.with_(waves_per_tile=self.waves_per_tile,
                          label=self._relabel(spec))

    def params(self) -> dict:
        return {"waves_per_tile": self.waves_per_tile}


class SetPipelineDepth(Transform):
    """Launch-geometry rewrite: change the double-buffering depth."""

    family = "geometry"

    def __init__(self, pipeline_depth: int) -> None:
        self.pipeline_depth = int(pipeline_depth)
        self.name = f"depth={self.pipeline_depth}"

    def legal(self, spec: WorkloadSpec) -> bool:
        # every acquisition path resolves an unset depth to 2
        # (``pipeline_depth or 2``), so compare the *effective* value —
        # "set depth to 2" on a default spec is a no-op, not a candidate
        return (spec.compiled is None and spec.hlo_text is None
                and (spec.pipeline_depth or 2) != self.pipeline_depth)

    def apply(self, spec: WorkloadSpec) -> WorkloadSpec:
        return spec.with_(pipeline_depth=self.pipeline_depth,
                          label=self._relabel(spec))

    def params(self) -> dict:
        return {"pipeline_depth": self.pipeline_depth}


class LaneInterleave(Transform):
    """Strided remap of the index stream across commit groups.

    Run-clustered duplicates (sorted or tiled streams) land in one
    commit group and serialize; reading the stream with a stride of
    ``size / COMMIT_GROUP`` interleaves distant elements into each
    group.  A pure gather — no scratch, no reduce — but the gather pass
    itself is the (stream-sized) cost.
    """

    name = "interleave-lanes"
    family = "remap"

    def legal(self, spec: WorkloadSpec) -> bool:
        if spec.indices is None:
            return False
        return np.asarray(spec.indices).size >= 2 * COMMIT_GROUP

    def apply(self, spec: WorkloadSpec) -> WorkloadSpec:
        idx = np.asarray(spec.indices).reshape(-1)
        n = (idx.size // COMMIT_GROUP) * COMMIT_GROUP
        head = idx[:n].reshape(COMMIT_GROUP, -1).T.reshape(-1)
        return spec.with_(indices=np.concatenate([head, idx[n:]]),
                          label=self._relabel(spec))

    def cost(self, spec: WorkloadSpec) -> TransformCost:
        return TransformCost(
            note="adds a strided gather pass over the index stream")


def default_catalog(
    *,
    waves_per_tile: Sequence[int] = (4, 8, 16, 32, 64),
    pipeline_depths: Sequence[int] = (2, 4),
    replication_factors: Sequence[int] = (2, 4, 8),
) -> list[Transform]:
    """The shipped catalog: every family, parameterized axes expanded.

    The cartesian half of "cartesian + beam": parameterized transforms
    (replication factor, geometry values) enter the catalog once per
    parameter value, so a search frontier enumerates the full parameter
    grid while the beam composes across *families*.  Illegal entries
    cost nothing — ``legal`` prunes them per spec at enumeration time.
    """
    catalog: list[Transform] = [ChannelRotation(), CasToFao(),
                                LaneInterleave()]
    catalog.extend(Replicate(f) for f in replication_factors)
    catalog.extend(SetWavesPerTile(w) for w in waves_per_tile)
    catalog.extend(SetPipelineDepth(d) for d in pipeline_depths)
    return catalog
