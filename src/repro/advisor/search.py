"""Candidate enumeration + frontier scoring for the optimization advisor.

The search is "cartesian + beam": the catalog's parameterized transforms
supply the cartesian axes (every replication factor / geometry value is
its own catalog entry) and a beam composes across transform *families*
— each level extends every surviving composition with every legal
transform whose family it does not already use, so depth 2 with the
default catalog explores e.g. ``rotate-channels + wpt=32`` but never
``wpt=16 + wpt=32``.

Scoring rides the machinery PR 4 made cheap: candidate counters are
acquired through the session's memo / persistent ``SweepCache`` (a
re-advised spec collects nothing), and **each frontier is scored by a
single columnar ``CounterFrame``/``profile_batch`` evaluation** — the
baseline rides along as row 0, so predicted speedups come from one
whole-array model pass per level, never per-candidate scalar profiling.
That batch-evaluation invariant is asserted by tests and the
``advise_search`` benchmark gate.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.advisor.report import AdvisorReport, Candidate
from repro.advisor.transforms import Transform, TransformCost, default_catalog
from repro.core import bottleneck


def _speedup(baseline_prof, prof) -> float:
    """``speedup_estimate`` that degrades broken candidates to 0.0.

    A candidate whose modeled window is zero is a broken rewrite, not an
    infinite win; ranking it last (0.0) keeps the search total-ordered
    without poisoning the report.
    """
    if float(np.max(prof.T_cycles)) <= 0.0:
        return 0.0
    return bottleneck.speedup_estimate(baseline_prof, prof)


class AdvisorSearch:
    """Beam search over transform compositions, scored by the queue model.

    ``session`` supplies everything: the device bundle, the counter
    provider, the in-process memo and optional persistent sweep cache,
    and the columnar batch evaluator.  ``catalog`` defaults to
    ``transforms.default_catalog()``; ``depth`` bounds composition
    length; ``beam_width`` bounds how many compositions each level
    extends.
    """

    def __init__(self, session, *, catalog: Optional[Sequence[Transform]]
                 = None, depth: int = 2, beam_width: int = 8) -> None:
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        if beam_width < 1:
            raise ValueError(f"beam_width must be >= 1, got {beam_width}")
        self.session = session
        self.catalog = list(catalog) if catalog is not None \
            else default_catalog()
        self.depth = depth
        self.beam_width = beam_width

    # -- enumeration ------------------------------------------------------

    def _extend(self, node: Candidate, seen: set) -> list[Candidate]:
        """All one-transform extensions of ``node`` (family-once rule)."""
        used = {t.family for t in node.transforms}
        out = []
        for t in self.catalog:
            if t.family in used or not t.legal(node.spec):
                continue
            new_spec = t.apply(node.spec)
            fp = new_spec.fingerprint()
            if fp is not None:
                # two orders of the same composition produce the same
                # spec content: enumerate it once
                if fp in seen:
                    continue
                seen.add(fp)
            # cost is judged on the spec the transform is APPLIED to:
            # Replicate's scratch/reduce annotations describe the bins it
            # multiplies, not the already-multiplied result
            out.append(Candidate(
                spec=new_spec, transforms=node.transforms + (t,),
                cost=TransformCost.merge([node.cost, t.cost(node.spec)])))
        return out

    # -- the search -------------------------------------------------------

    def search(self, spec, *, top_k: int = 5, validate_top: int = 0,
               parallel: Optional[int] = None) -> AdvisorReport:
        """Search transform space around ``spec``; return the ranked report.

        ``top_k`` bounds how many candidates the report renders (all
        evaluated candidates stay on ``AdvisorReport.candidates``);
        ``validate_top`` re-validates that many of the top-ranked
        kernel-source candidates through the ``kernel`` provider (paper
        §5's model-vs-measured check); ``parallel`` spreads counter
        collection over a thread pool like ``Session.sweep``.
        """
        sess = self.session
        stats_before = dict(sess.stats)
        base_cset = sess.collect_cached(spec)
        baseline_prof = None
        survivors = [Candidate(spec=spec, transforms=())]
        evaluated: list[Candidate] = []
        seen = {spec.fingerprint()} - {None}
        frontiers = batch_evals = 0

        for _level in range(self.depth):
            frontier: list[Candidate] = []
            for node in survivors:
                frontier.extend(self._extend(node, seen))
            if not frontier:
                break
            frontiers += 1
            csets = self._collect(frontier, parallel)
            # one columnar model evaluation scores the whole frontier;
            # the baseline rides along as row 0 so speedups are computed
            # against numbers from the very same batch pass
            profs = sess.profile_sets([base_cset] + csets)
            batch_evals += 1
            if baseline_prof is None:
                baseline_prof = profs[0]
            for cand, prof in zip(frontier, profs[1:]):
                cand.profile = prof
                cand.speedup = _speedup(baseline_prof, prof)
                cand.verdict = bottleneck.classify(prof)
            evaluated.extend(frontier)
            survivors = sorted(frontier, key=_rank_key)[:self.beam_width]

        if baseline_prof is None:
            # no transform was legal: the report is just the baseline
            baseline_prof = sess.profile_sets([base_cset])[0]
            batch_evals += 1

        ranked = sorted(evaluated, key=_rank_key)
        report = AdvisorReport(
            device=sess.device.name,
            baseline_label=spec.label,
            baseline_profile=baseline_prof,
            baseline_verdict=bottleneck.classify(baseline_prof),
            candidates=ranked,
            top_k=top_k,
            stats=_stats(stats_before, sess.stats, len(evaluated),
                         frontiers, batch_evals),
        )
        if validate_top > 0:
            self._validate_top(report, validate_top)
        return report

    def _collect(self, frontier: Sequence[Candidate],
                 parallel: Optional[int]) -> list:
        # one batch resolution per frontier: memo / persistent-cache hits
        # in bulk, misses through provider.collect_batch (``parallel``
        # only threads providers that fall back to a scalar loop)
        return self.session.collect_cached_batch(
            [c.spec for c in frontier], parallel=parallel)

    def _validate_top(self, report: AdvisorReport, k: int) -> None:
        """Paper-§5 check on the top-k: modeled vs measured counters.

        Only kernel-source candidates can run the instrumented-kernel
        provider; others are skipped (they stay unvalidated, which the
        report renders as such).
        """
        for cand in report.top(k):
            if cand.spec.kernel is None:
                continue
            cand.validation = self.session.validate(
                cand.spec, providers=("trace", "kernel"))


def _rank_key(c: Candidate):
    """Total order: speedup desc, then fewer transforms, then label.

    The tie-breaks make the ranking deterministic — same spec + seed
    must reproduce the identical report (tested).
    """
    return (-c.speedup, len(c.transforms), c.label)


def _stats(before: dict, after: dict, candidates: int, frontiers: int,
           batch_evals: int) -> dict:
    collection = {k: after[k] - before.get(k, 0) for k in after}
    return {"candidates": candidates, "frontiers": frontiers,
            "batch_evals": batch_evals, **collection}
