"""Model-driven optimization advisor: search workload transforms, rank fixes.

The paper stops at diagnosis ("the scatter unit is your bottleneck");
this layer turns the same queueing model prescriptive.  A declarative
``Transform`` catalog rewrites ``WorkloadSpec``s without touching kernel
code (channel rotation à la ``hist2``, bin replication, CAS→FAO
substitution, launch geometry, lane interleave), a beam search
enumerates compositions, and every frontier is scored by ONE columnar
``profile_batch`` evaluation through the session's provider/memo/
``SweepCache`` machinery — the predicted speedups, post-transform
bottlenecks, and cost annotations come back as a ranked
``AdvisorReport``::

    from repro.analysis import Session, WorkloadSpec
    sess = Session("v5e")
    report = sess.advise(WorkloadSpec.from_histogram(img, label="hist",
                                                     variant="hist"))
    print(report.render())        # rank 1: rotate-channels, x1.27 ...

Or from the command line::

    python -m repro advise --workload histogram --dist solid \
        --pixels 2^16 --top-k 5 --validate-top 1
"""

from repro.advisor.report import AdvisorReport, Candidate  # noqa: F401
from repro.advisor.search import AdvisorSearch  # noqa: F401
from repro.advisor.transforms import (  # noqa: F401
    CasToFao,
    ChannelRotation,
    LaneInterleave,
    Replicate,
    SetPipelineDepth,
    SetWavesPerTile,
    Transform,
    TransformCost,
    default_catalog,
)
