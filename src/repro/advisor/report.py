"""Ranked advisor output: candidates, predicted fixes, rendered reports.

The paper's case study (§5) explains *why* ``hist2`` wins; an
``AdvisorReport`` turns that explanatory power prescriptive: every
evaluated transform composition with its model-predicted speedup, the
predicted post-transform bottleneck (with a warning when the transform
*moves* the bottleneck — the §4.1 shift, now forecast instead of
observed), the rewrite's cost annotations, and optionally the paper-§5
model-vs-measured validation of the top candidates.

Renderable ``text`` / ``json`` / ``csv``; csv rows are ragged (each
candidate only carries its own transforms' ``param_*`` columns) and go
through the same union-header helper sweep csv uses.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Optional

import numpy as np

from repro.advisor.transforms import Transform, TransformCost
from repro.analysis.render import rows_to_csv


@dataclasses.dataclass
class Candidate:
    """One evaluated transform composition."""

    spec: "object"                         # the rewritten WorkloadSpec
    transforms: tuple[Transform, ...]
    profile: Optional[object] = None       # predicted WorkloadProfile
    speedup: float = 1.0                   # modeled T(base) / T(candidate)
    verdict: Optional[object] = None       # BottleneckVerdict (with hint)
    cost: TransformCost = dataclasses.field(default_factory=TransformCost)
    validation: Optional[object] = None    # ValidationReport (top-k only)

    @property
    def label(self) -> str:
        return self.spec.label

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(t.name for t in self.transforms)

    @property
    def families(self) -> tuple[str, ...]:
        return tuple(t.family for t in self.transforms)

    def params(self) -> dict:
        """Merged transform parameters, ``param_``-prefixed for rows."""
        out: dict = {}
        for t in self.transforms:
            for k, v in t.params().items():
                out[f"param_{k}"] = v
        return out

    def summary(self) -> dict:
        """Compact dict of the candidate — what audit/lint findings
        attach as ``Finding.advice`` (and SARIF ``properties.advise``)."""
        prof = self.profile
        return {
            "transforms": "+".join(self.names),
            "families": "+".join(self.families),
            "predicted_speedup": round(float(self.speedup), 4),
            "predicted_bottleneck": prof.bottleneck if prof else "",
            "predicted_scatter_U": round(
                float(prof.scatter_utilization), 4) if prof else 0.0,
        }


@dataclasses.dataclass
class AdvisorReport:
    """The ranked frontier + baseline context (see module docstring)."""

    device: str
    baseline_label: str
    baseline_profile: object               # WorkloadProfile
    baseline_verdict: object               # BottleneckVerdict
    candidates: list[Candidate]            # every evaluated one, ranked
    top_k: int = 5
    stats: dict = dataclasses.field(default_factory=dict)

    def __len__(self) -> int:
        return len(self.candidates)

    def top(self, k: Optional[int] = None) -> list[Candidate]:
        return self.candidates[:self.top_k if k is None else k]

    @property
    def best(self) -> Optional[Candidate]:
        return self.candidates[0] if self.candidates else None

    # -- flat rows (the csv/json payload) ---------------------------------

    def to_rows(self, limit: Optional[int] = None) -> list[dict]:
        """One flat record per ranked candidate (top-k by default).

        Ragged by construction: ``param_*`` columns depend on the
        candidate's transforms and ``validation_*`` columns exist only
        for validated candidates — render through the union-header csv
        helper, never ``fieldnames=rows[0]``.
        """
        base_bn = self.baseline_verdict.bottleneck
        rows = []
        for rank, c in enumerate(self.top(limit), start=1):
            prof = c.profile
            row = {
                "rank": rank,
                "label": c.label,
                "transforms": "+".join(c.names),
                "families": "+".join(c.families),
                "predicted_speedup": float(c.speedup),
                "predicted_bottleneck": prof.bottleneck if prof else "",
                # U of the unit named as the bottleneck (the verdict's
                # number) — pairing the hbm bottleneck with the scatter
                # model's utilization would read as a contradiction
                "predicted_U": (c.verdict.utilization if c.verdict
                                else 0.0),
                "predicted_scatter_U": (prof.scatter_utilization
                                        if prof else 0.0),
                "predicted_e": prof.e if prof else 0.0,
                "shifts_bottleneck": bool(prof
                                          and prof.bottleneck != base_bn),
                "scratch_bytes": c.cost.scratch_bytes,
                "reduce_flops": c.cost.reduce_flops,
                "cost_note": c.cost.note,
            }
            row.update(c.params())
            if c.validation is not None:
                row["validation_e_rel_err"] = c.validation.rel_err(
                    "kernel", "e")
                row["validation_max_rel_err"] = c.validation.max_rel_err
            rows.append(row)
        return rows

    # -- renderers --------------------------------------------------------

    def render(self, fmt: str = "text") -> str:
        if fmt == "json":
            b = self.baseline_profile
            payload = {
                "device": self.device,
                "baseline": {
                    "label": self.baseline_label,
                    "bottleneck": self.baseline_verdict.bottleneck,
                    "utilization": self.baseline_verdict.utilization,
                    "scatter_U": b.scatter_utilization,
                    "e": b.e,
                    "T_cycles": float(np.max(b.T_cycles)),
                    "hint": (dataclasses.asdict(self.baseline_verdict.hint)
                             if self.baseline_verdict.hint else None),
                },
                "candidates": self.to_rows(),
                "stats": self.stats,
            }
            return json.dumps(payload, indent=2)
        if fmt == "csv":
            return rows_to_csv(self.to_rows())
        if fmt == "text":
            return self._render_text()
        raise ValueError(f"unknown report format {fmt!r} "
                         "(expected 'text', 'json' or 'csv')")

    def _render_text(self) -> str:
        lines = []
        b = self.baseline_profile
        n = self.stats.get("candidates", len(self.candidates))
        lines.append(
            f"== advisor: {self.baseline_label} on {self.device} "
            f"({n} candidate{'s' if n != 1 else ''}, "
            f"{self.stats.get('frontiers', 0)} frontier(s)) ==")
        hint = self.baseline_verdict.hint
        lines.append(
            f"baseline: bottleneck={self.baseline_verdict.bottleneck}  "
            f"U={self.baseline_verdict.utilization:6.2%}  e={b.e:.2f}  "
            f"T={float(np.max(b.T_cycles)):.0f} cyc"
            + (f"  [{hint.compact()}]" if hint else ""))
        for row in self.to_rows():
            cost_bits = []
            if row["scratch_bytes"]:
                cost_bits.append(f"+{row['scratch_bytes']:.0f}B scratch")
            if row["reduce_flops"]:
                cost_bits.append(f"+{row['reduce_flops']:.0f} reduce flops")
            cost = ", ".join(cost_bits) if cost_bits else "free"
            shift = "  ! shifts bottleneck" if row["shifts_bottleneck"] \
                else ""
            lines.append(
                f"rank {row['rank']:>2}  x{row['predicted_speedup']:.3f}  "
                f"{row['transforms']:<32} -> "
                f"{row['predicted_bottleneck']} "
                f"U={row['predicted_U']:6.2%}  [{cost}]{shift}")
            if row["cost_note"]:
                lines.append(f"          note: {row['cost_note']}")
            if "validation_e_rel_err" in row:
                lines.append(
                    f"          validated (kernel vs trace): "
                    f"e rel err={row['validation_e_rel_err']:.2%}, "
                    f"max rel err={row['validation_max_rel_err']:.2%}")
        collected = self.stats.get("collected")
        if collected is not None:
            lines.append(
                f"cache: {collected} collected, "
                f"{self.stats.get('memo_hits', 0)} memo hits, "
                f"{self.stats.get('disk_hits', 0)} disk hits")
        return "\n".join(lines)
